#!/usr/bin/env python
"""Gate a serve-engine metrics JSON against a committed baseline.

CI runs the serve smoke, then::

  python tools/metrics_diff.py serve_metrics.json benchmarks/baselines/serve.json

and fails the build when the current run regresses more than
``--tolerance`` (default 10%) on throughput, or when the plan cache fell
out of steady state (``steady_state: false`` — lazy solves inside the
decode loop, the perf cliff the whole planning layer exists to prevent).

Throughput compares ``tokens_per_tick`` when both sides have it (exact
under any clock; SimClock smokes report wall-clock throughput as null)
and falls back to ``tokens_per_sec``. Regenerate the baseline after an
intentional perf change with ``--update-baseline``.
"""
from __future__ import annotations

import argparse
import json
import sys


def _throughput(metrics: dict) -> tuple[str, float | None]:
    """(key, value) of the preferred throughput measure in a metrics dict."""
    agg = metrics.get("aggregate", metrics)
    for key in ("tokens_per_tick", "tokens_per_sec"):
        if agg.get(key) is not None:
            return key, float(agg[key])
    return "tokens_per_tick", None


def diff_nested(cur, base, *, tolerance: float, path: str = "") -> list[str]:
    """None-safe recursive comparison of a nested metrics section.

    Numeric leaves present on *both* sides must agree within ``tolerance``
    (relative; absolute when the baseline is 0). Everything that cannot be
    compared is skipped, never failed: ``None`` on either side (a ratio
    whose denominator never moved), a key missing from one side (schema
    grew), a whole section missing from one side (traced vs untraced run),
    and non-numeric leaves. That keeps the gate meaningful on sections like
    ``timing`` and ``attribution`` that only traced runs carry.
    """
    if cur is None or base is None:
        return []
    if isinstance(cur, dict) and isinstance(base, dict):
        out: list[str] = []
        for k in sorted(set(cur) & set(base)):
            sub = f"{path}.{k}" if path else str(k)
            out += diff_nested(cur[k], base[k], tolerance=tolerance, path=sub)
        return out
    if isinstance(cur, list) and isinstance(base, list):
        out = []
        for i, (c, b) in enumerate(zip(cur, base)):
            out += diff_nested(c, b, tolerance=tolerance, path=f"{path}[{i}]")
        return out
    num = (int, float)
    if (isinstance(cur, num) and isinstance(base, num)
            and not isinstance(cur, bool) and not isinstance(base, bool)):
        delta = abs(cur - base)
        bound = tolerance * abs(base) if base else tolerance
        if delta > bound:
            return [f"{path}: {cur} vs baseline {base} "
                    f"(delta {delta:.4g} > {bound:.4g})"]
    return []


def diff(current: dict, baseline: dict, tolerance: float,
         sections: tuple[str, ...] = ()) -> list[str]:
    """Regression messages (empty = pass)."""
    problems: list[str] = []
    for name in sections:
        problems += diff_nested(
            current.get(name), baseline.get(name),
            tolerance=tolerance, path=name)
    plan = current.get("plan_cache", {})
    if plan.get("steady_state") is False:
        problems.append(
            f"plan cache fell out of steady state: "
            f"{plan.get('lazy_solves')} lazy solves, "
            f"{plan.get('misses')} misses in the decode loop")
    cur_key, cur = _throughput(current)
    base_key, base = _throughput(baseline)
    if base is None:
        return problems  # baseline carries no throughput — nothing to gate
    if cur is None:
        problems.append(f"current run reports no throughput ({cur_key} "
                        f"and tokens_per_sec both null)")
        return problems
    if cur_key != base_key:
        # one side pre-dates the tick twin — compare the shared measure
        cur_key = base_key = "tokens_per_sec"
        cur = current.get("aggregate", current).get(cur_key)
        base = baseline.get("aggregate", baseline).get(base_key)
        if cur is None or base is None:
            return problems
    floor = base * (1.0 - tolerance)
    if cur < floor:
        problems.append(
            f"{cur_key} regressed {1 - cur / base:.1%} (> {tolerance:.0%}): "
            f"{cur:.3f} vs baseline {base:.3f}")
    return problems


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("current", help="metrics JSON from the run under test")
    ap.add_argument("baseline", help="committed baseline metrics JSON")
    ap.add_argument("--tolerance", type=float, default=0.10, metavar="FRAC",
                    help="allowed fractional throughput drop (default 0.10)")
    ap.add_argument("--update-baseline", action="store_true",
                    help="overwrite the baseline with the current metrics "
                         "instead of diffing (intentional perf change)")
    ap.add_argument("--sections", default="", metavar="A,B,C",
                    help="also compare these top-level sections leaf-by-"
                         "leaf (None-safe; e.g. timing,attribution — "
                         "sections or leaves missing on either side are "
                         "skipped, not failed)")
    args = ap.parse_args(argv)

    with open(args.current) as f:
        current = json.load(f)
    if args.update_baseline:
        with open(args.baseline, "w") as f:
            json.dump(current, f, indent=2)
            f.write("\n")
        print(f"baseline updated: {args.baseline}")
        return 0
    with open(args.baseline) as f:
        baseline = json.load(f)
    sections = tuple(s for s in args.sections.split(",") if s)
    problems = diff(current, baseline, args.tolerance, sections)
    cur_key, cur = _throughput(current)
    _, base = _throughput(baseline)
    print(f"{cur_key}: current={cur} baseline={base} "
          f"steady_state={current.get('plan_cache', {}).get('steady_state')}")
    for p in problems:
        print(f"FAIL: {p}", file=sys.stderr)
    return 1 if problems else 0


if __name__ == "__main__":
    raise SystemExit(main())
